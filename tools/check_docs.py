#!/usr/bin/env python3
"""Docs link/reference checker (no dependencies) — the CI ``docs`` job.

Scans ``docs/*.md`` and ``README.md`` for:

  * markdown links ``[text](target)``: every internal target (no URL
    scheme, ``#anchor`` stripped) must exist relative to the file;
  * code references in backticks that look like repo paths
    (``src/repro/core/fs.py``, ``tests/test_property.py``,
    ``docs/ARCHITECTURE.md``, ...): the path must exist at the repo root;
  * dotted module references in backticks (``repro.sim.kvmodel``,
    ``benchmarks.run``): the module must resolve under ``src/`` or the
    repo root;
  * benchmark coverage: every benchmark module in ``benchmarks/`` (except
    the harness/helpers) must be documented in ``docs/BENCHMARKS.md`` —
    an undocumented figure module fails the docs job;
  * analysis coverage: every pass registered in ``tools.reprolint.passes``
    must be documented in ``docs/ANALYSIS.md`` — adding a pass without
    documenting it fails the docs job.

Exit code = number of broken references; each is printed as
``file:line: message``.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
# backticked tokens that look like repo file paths: at least one '/', a
# known extension, and no spaces/wildcards/placeholders
PATH_RE = re.compile(r"^[\w./-]+\.(py|md|toml|yml|yaml|csv|json|jsonl)$")
MODULE_RE = re.compile(r"^(repro|benchmarks|tests|tools)(\.\w+)+$")


def check_file(md: Path) -> list:
    errors = []
    text = md.read_text(encoding="utf-8")
    for lineno, line in enumerate(text.splitlines(), 1):
        for m in LINK_RE.finditer(line):
            target = m.group(1).strip()
            if "://" in target or target.startswith(("mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (md.parent / path).exists():
                errors.append(f"{md.relative_to(ROOT)}:{lineno}: "
                              f"broken link -> {target}")
        for m in CODE_RE.finditer(line):
            tok = m.group(0)[1:-1].strip()
            if "*" in tok or "{" in tok or " " in tok:
                continue  # glob/placeholder/command, not a reference
            if PATH_RE.match(tok) and "/" in tok:
                if not (ROOT / tok).exists():
                    errors.append(f"{md.relative_to(ROOT)}:{lineno}: "
                                  f"missing file reference -> {tok}")
            elif MODULE_RE.match(tok):
                rel = Path(tok.replace(".", "/"))
                candidates = [
                    ROOT / "src" / rel.with_suffix(".py"),
                    ROOT / "src" / rel / "__init__.py",
                    ROOT / rel.with_suffix(".py"),
                    ROOT / rel / "__init__.py",
                ]
                if not any(c.exists() for c in candidates):
                    errors.append(f"{md.relative_to(ROOT)}:{lineno}: "
                                  f"unresolvable module reference -> {tok}")
    return errors


BENCH_HELPERS = {"run.py", "common.py", "__init__.py"}


def check_bench_coverage() -> list:
    """Every benchmark module must have a docs/BENCHMARKS.md mention."""
    doc = ROOT / "docs" / "BENCHMARKS.md"
    if not doc.exists():
        return ["docs/BENCHMARKS.md: missing (benchmark docs required)"]
    text = doc.read_text(encoding="utf-8")
    errors = []
    for mod in sorted((ROOT / "benchmarks").glob("*.py")):
        if mod.name in BENCH_HELPERS:
            continue
        if mod.name not in text:
            errors.append(f"docs/BENCHMARKS.md: benchmarks/{mod.name} "
                          "exists but is undocumented")
    return errors


def check_analysis_coverage() -> list:
    """Every registered reprolint pass must be documented in ANALYSIS.md."""
    doc = ROOT / "docs" / "ANALYSIS.md"
    if not doc.exists():
        return ["docs/ANALYSIS.md: missing (static-analysis docs required)"]
    if str(ROOT) not in sys.path:
        sys.path.insert(0, str(ROOT))
    from tools.reprolint.passes import PASSES
    text = doc.read_text(encoding="utf-8")
    return [
        f"docs/ANALYSIS.md: reprolint pass `{rule}` is registered but "
        "undocumented"
        for rule in sorted(PASSES) if f"`{rule}`" not in text
    ]


def main() -> int:
    files = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]
    errors = []
    for md in files:
        errors.extend(check_file(md))
    errors.extend(check_bench_coverage())
    errors.extend(check_analysis_coverage())
    for e in errors:
        print(e)
    print(f"checked {len(files)} files, {len(errors)} broken references")
    return min(len(errors), 125)


if __name__ == "__main__":
    sys.exit(main())
