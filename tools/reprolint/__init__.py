"""reprolint — the repo's lease/lock/layering static-analysis plane.

CLI:   python -m tools.reprolint src/ benchmarks/ examples/
API:   from tools.reprolint import run, Finding, PASSES

See docs/ANALYSIS.md for the pass catalog, suppression syntax and the
baseline mechanism.
"""
from tools.reprolint.core import (AnalysisResult, DEFAULT_EXCLUDES,  # noqa
                                  Finding, format_baseline, load_baseline,
                                  run)
from tools.reprolint.passes import PASSES  # noqa
