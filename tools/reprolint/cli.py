"""reprolint CLI — ``python -m tools.reprolint [paths...]``.

Exit codes: 0 = clean (every finding suppressed-with-reason or baselined),
1 = unsuppressed findings, 2 = usage error. The CI ``static-analysis`` job
gates on this; docs/ANALYSIS.md documents each pass and the suppression /
baseline mechanics.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Optional, Sequence

from tools.reprolint.core import (DEFAULT_EXCLUDES, REPO_ROOT, format_baseline,
                                  load_baseline, run)

DEFAULT_PATHS = ("src", "benchmarks", "examples")
DEFAULT_BASELINE = REPO_ROOT / "tools" / "reprolint" / "baseline.txt"


def main(argv: Optional[Sequence[str]] = None) -> int:
    from tools.reprolint.passes import PASSES

    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="AST static analysis for the repo's lease/lock/layering "
                    "discipline (docs/ANALYSIS.md).",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to analyze (default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--rules", help="comma-separated pass ids (default: all)")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="baseline file of grandfathered findings")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline with the current findings "
                         "and exit 0")
    ap.add_argument("--exclude", action="append", default=[],
                    metavar="GLOB", help="additional path globs to skip")
    ap.add_argument("--no-default-excludes", action="store_true",
                    help=f"do not skip {DEFAULT_EXCLUDES} (fixture corpus)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list-passes", action="store_true")
    args = ap.parse_args(argv)

    if args.list_passes:
        for rule, mod in sorted(PASSES.items()):
            print(f"{rule:22s} {mod.DOC}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
    exclude = tuple(args.exclude) + (
        () if args.no_default_excludes else DEFAULT_EXCLUDES
    )
    paths = args.paths or [REPO_ROOT / p for p in DEFAULT_PATHS]
    try:
        baseline = load_baseline(args.baseline)
        res = run(paths, rules=rules, exclude=exclude, baseline=baseline)
    except (FileNotFoundError, ValueError) as e:
        print(f"reprolint: error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        args.baseline.write_text(
            format_baseline(res.findings + res.baselined), encoding="utf-8"
        )
        print(f"reprolint: baseline written to {args.baseline} "
              f"({len(res.findings) + len(res.baselined)} entries)")
        return 0

    if args.format == "json":
        print(json.dumps({
            "findings": [vars(f) | {"fingerprint": f.fingerprint}
                         for f in res.findings],
            "suppressed": len(res.suppressed),
            "baselined": len(res.baselined),
            "files": res.files,
        }, indent=2))
    else:
        for f in res.findings:
            print(f.render())
        status = "clean" if res.ok else f"{len(res.findings)} finding(s)"
        print(f"reprolint: {status} across {res.files} file(s) "
              f"({len(res.suppressed)} suppressed, "
              f"{len(res.baselined)} baselined)")
    return 0 if res.ok else 1
