"""reprolint core — findings, suppressions, baseline, file walking.

The analyzer encodes the repo's correctness conventions (lease discipline,
no blocking under locks, journal-before-mutate, layering DAG, deprecated
API) as AST passes over the source tree. This module is the harness: it
walks the paths, parses each module once, dispatches the registered passes
(``tools.reprolint.passes``), and post-filters the findings through inline
suppressions and the checked-in baseline.

Inline suppression syntax (on the flagged line, or a comment line directly
above it)::

    some_flagged_code()  # reprolint: allow[rule-id] why this is legitimate

The reason string is REQUIRED — an empty reason does not suppress (the
finding is reported with a note instead), so every grandfathered site
documents itself.

Baseline: a checked-in file of fingerprinted findings that are known and
tolerated (target: empty). Fingerprints hash the rule id + the flagged
source line, so unrelated line-number drift does not invalidate them.
"""
from __future__ import annotations

import ast
import re
import zlib
from dataclasses import dataclass, field
from fnmatch import fnmatch
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

REPO_ROOT = Path(__file__).resolve().parents[2]

# fixture corpus of intentionally-bad examples: excluded by PATH (never by
# inline comments — the fixtures must stay byte-exact bad examples)
DEFAULT_EXCLUDES: Tuple[str, ...] = ("*__pycache__*", "*lint_fixtures*")

_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*allow\[([a-z0-9_,-]+)\]\s*(.*?)\s*$"
)


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    path: str  # repo-relative posix path (or as given, outside the repo)
    line: int  # 1-based
    rule: str
    message: str
    snippet: str = ""  # stripped source line (fingerprint input)

    @property
    def fingerprint(self) -> str:
        return f"{zlib.crc32((self.rule + chr(0) + self.snippet).encode()):08x}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class ParsedModule:
    """One parsed source file handed to every pass."""

    path: Path
    rel: str  # repo-relative posix path
    text: str
    lines: List[str]
    tree: ast.Module
    module: Optional[str]  # dotted module name (``src/``-rooted), or None

    def finding(self, node_or_line, rule: str, message: str) -> Finding:
        line = getattr(node_or_line, "lineno", node_or_line)
        snippet = self.lines[line - 1].strip() if 0 < line <= len(self.lines) else ""
        return Finding(self.rel, line, rule, message, snippet)


@dataclass
class Suppression:
    line: int  # line the comment sits on
    rules: Tuple[str, ...]
    reason: str
    used: bool = False


@dataclass
class AnalysisResult:
    findings: List[Finding] = field(default_factory=list)  # actionable
    suppressed: List[Finding] = field(default_factory=list)
    baselined: List[Finding] = field(default_factory=list)
    files: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings


def rel_path(path: Path) -> str:
    path = path.resolve()
    try:
        return path.relative_to(REPO_ROOT).as_posix()
    except ValueError:
        return path.as_posix()


def module_name(rel: str) -> Optional[str]:
    """Dotted module for a ``src/``-rooted file (layering pass key).
    ``src/repro/core/fs.py`` → ``repro.core.fs``; the LAST ``src`` path
    segment wins so fixture trees like ``tests/lint_fixtures/.../src/...``
    map the same way the real tree does."""
    parts = Path(rel).parts
    if "src" not in parts:
        return None
    idx = len(parts) - 1 - list(reversed(parts)).index("src")
    mod = list(parts[idx + 1 :])
    if not mod or not mod[-1].endswith(".py"):
        return None
    mod[-1] = mod[-1][:-3]
    if mod[-1] == "__init__":
        mod.pop()
    return ".".join(mod) if mod else None


def iter_py_files(paths: Sequence, exclude: Sequence[str]) -> List[Path]:
    """Expand files/directories into a sorted, de-duplicated .py file list,
    skipping anything whose repo-relative path matches an exclude glob."""
    out: List[Path] = []
    seen = set()

    def want(p: Path) -> bool:
        r = rel_path(p)
        return not any(fnmatch(r, pat) or fnmatch(p.name, pat)
                       for pat in exclude)

    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            cands = sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            cands = [p]
        else:
            raise FileNotFoundError(f"not a .py file or directory: {raw}")
        for c in cands:
            rc = c.resolve()
            if rc not in seen and want(rc):
                seen.add(rc)
                out.append(rc)
    return out


def parse_module(path: Path) -> Tuple[Optional[ParsedModule], Optional[Finding]]:
    rel = rel_path(path)
    text = path.read_text(encoding="utf-8")
    try:
        tree = ast.parse(text, filename=str(path))
    except SyntaxError as e:
        return None, Finding(rel, e.lineno or 1, "parse-error",
                             f"syntax error: {e.msg}")
    return ParsedModule(path, rel, text, text.splitlines(), tree,
                        module_name(rel)), None


def collect_suppressions(mod: ParsedModule) -> Dict[int, Suppression]:
    """{effective line: Suppression}. A suppression comment covers the line
    it sits on; a comment-only line also covers the next line (so long
    statements can carry the comment above them)."""
    out: Dict[int, Suppression] = {}
    for i, raw in enumerate(mod.lines, start=1):
        m = _SUPPRESS_RE.search(raw)
        if not m:
            continue
        sup = Suppression(i, tuple(r.strip() for r in m.group(1).split(",")),
                          m.group(2).strip())
        out[i] = sup
        if raw.lstrip().startswith("#"):  # standalone comment: covers next line
            out.setdefault(i + 1, sup)
    return out


def load_baseline(path: Path) -> set:
    """Baseline lines: ``rule<TAB>path<TAB>fingerprint`` (+ ``#`` comments)."""
    entries = set()
    if not path.exists():
        return entries
    for raw in path.read_text(encoding="utf-8").splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split("\t")
        if len(parts) != 3:
            raise ValueError(f"malformed baseline line: {raw!r}")
        entries.add((parts[0], parts[1], parts[2]))
    return entries


def format_baseline(findings: Iterable[Finding]) -> str:
    header = (
        "# reprolint baseline — grandfathered findings (target: EMPTY).\n"
        "# Each line: rule<TAB>path<TAB>fingerprint. Regenerate with\n"
        "#   python -m tools.reprolint --write-baseline <paths>\n"
    )
    body = "".join(
        f"{f.rule}\t{f.path}\t{f.fingerprint}\n"
        for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule))
    )
    return header + body


def run(paths: Sequence, *, rules: Optional[Sequence[str]] = None,
        exclude: Sequence[str] = DEFAULT_EXCLUDES,
        baseline: Optional[set] = None) -> AnalysisResult:
    """Programmatic entry point: analyze ``paths`` with the selected passes
    (default: all registered) and return the filtered result."""
    from tools.reprolint.passes import PASSES

    unknown = set(rules or ()) - set(PASSES)
    if unknown:
        raise ValueError(f"unknown rule(s): {sorted(unknown)}")
    active = {r: PASSES[r] for r in (rules or PASSES)}
    baseline = baseline or set()
    res = AnalysisResult()
    for path in iter_py_files(paths, exclude):
        res.files += 1
        mod, err = parse_module(path)
        if err is not None:
            res.findings.append(err)
            continue
        sups = collect_suppressions(mod)
        for rule, mod_pass in active.items():
            for f in mod_pass.check(mod):
                assert f.rule == rule, f"{mod_pass} emitted rule {f.rule}"
                sup = sups.get(f.line)
                if sup is not None and f.rule in sup.rules:
                    if not sup.reason:
                        res.findings.append(Finding(
                            f.path, f.line, f.rule,
                            f.message + " (suppression comment needs a "
                            "reason string — empty reasons do not suppress)",
                            f.snippet))
                        continue
                    sup.used = True
                    res.suppressed.append(f)
                elif (f.rule, f.path, f.fingerprint) in baseline:
                    res.baselined.append(f)
                else:
                    res.findings.append(f)
    res.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return res


# ---------------------------------------------------------------- AST utils
def dotted(node: ast.AST) -> Optional[str]:
    """``self.fs.grant_lease`` for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    """Terminal name of a call: ``fs.grant_lease(...)`` → ``grant_lease``."""
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    if isinstance(call.func, ast.Name):
        return call.func.id
    return None


def own_nodes(fn_body: Sequence[ast.stmt]) -> Iterable[ast.AST]:
    """Every node in a statement list EXCLUDING nested function/class
    bodies — 'runs when this body runs', which is what lock regions and
    release-path analysis care about (a nested def is deferred work)."""
    stack: List[ast.AST] = list(fn_body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue  # do not descend into deferred/contained bodies
        stack.extend(ast.iter_child_nodes(node))


def function_bodies(tree: ast.Module):
    """Yield (name, body) for the module top level and every (nested)
    function — each body analyzed with ``own_nodes`` semantics."""
    yield "<module>", tree.body
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node.name, node.body
