"""Pass registry. Every pass module exports RULE (id), DOC (one-liner)
and ``check(mod: ParsedModule) -> Iterable[Finding]``; registration here
is what makes a pass exist (the CLI, the docs checker and the test corpus
all enumerate this dict)."""
from tools.reprolint.passes import (deprecated, journal, layering, leases,
                                    locks)

PASSES = {
    p.RULE: p for p in (leases, locks, journal, layering, deprecated)
}

assert len(PASSES) == 5, "pass RULE ids must be unique"
