"""deprecated-api — statically catch callers of the legacy submit shims.

PR 7 consolidated the offload plane onto ONE entry point,
``TaskOffloader.submit(specs, *, stream, reroute, async_)``; the old names
survive only as warning shims. The runtime gate (``pytest.ini`` turns the
shims' DeprecationWarning into an error for ``repro.*`` callers) only
fires on code a test actually executes — benchmarks, examples, tools and
cold paths sail through. This pass closes that gap: ANY call of a shim
name, anywhere the analyzer scans, is flagged at the call site.

Back-compat tests that exercise the shims on purpose carry
``# reprolint: allow[deprecated-api] <reason>`` suppressions.
"""
from __future__ import annotations

import ast
from typing import Iterable

from tools.reprolint.core import Finding, ParsedModule

RULE = "deprecated-api"
DOC = ("call sites of the deprecated submit_task / submit_many / "
       "submit_async shims (use TaskOffloader.submit)")

SHIMS = {
    "submit_task": "submit(spec) or submit(task, *args)",
    "submit_many": "submit(specs) / submit(specs, stream=True)",
    "submit_async": "submit(spec, async_=True)",
}


def check(mod: ParsedModule) -> Iterable[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        if not isinstance(node.func, ast.Attribute):
            continue  # bare names: the shim defs/registrations themselves
        name = node.func.attr
        if name in SHIMS:
            yield mod.finding(
                node, RULE,
                f".{name}() is a deprecated shim — use "
                f"TaskOffloader.{SHIMS[name]}",
            )
