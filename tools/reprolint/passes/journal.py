"""journal-before-mutate — extent-state mutations need a lease fence first.

Within the extent/lease core (``fs.py``, ``extents.py``, ``rebalance.py``),
freeing or trimming blocks while a lease might be outstanding corrupts the
no-DLM story: a target mid-write (write lease) or mid-read (read lease)
would see its blocks recycled under it, and a crash between the mutation
and the journal record would leave the on-device lease journal pointing at
state that no longer exists.

The same discipline covers the remote-memory cache tier (``memtier.py``
and its ``fs.py`` call sites): a cache invalidation or fence riding a
free/trim path (``*.memtier.invalidate(...)``, ``*.memtier.fence(...)``)
is itself a coherence mutation — issued without the lease fence first, it
could race a grant and leave the tier serving pre-fence bytes.

The checkable discipline: every call to a block-state mutator
(``*.extmgr.free(...)``, ``*.dev.trim(...)``, ``*.memtier.invalidate(...)``,
``*.memtier.fence(...)``) must be *dominated* — earlier
in the same function body, nested defs excluded — by a lease fence:

  * a lease check (``_check_not_leased``), or
  * a scoped/journaled acquisition (``lease_scope`` / ``write_lease`` /
    ``read_lease`` / ``grant_lease``), or
  * a lease-journal record call (``append_grant`` / ``append_release`` /
    ``compact`` / ``replay`` / ``drop_outstanding`` on a journal receiver).

Dominance is linear (guard line ≤ mutator line), which matches how the
core is written: the guard runs at the top of the critical section, the
mutation at the bottom. ``mount``-time rebuilds allocate with ``carve``
(not a mutator) so fresh-mount paths are naturally out of scope.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Tuple

from tools.reprolint.core import (Finding, ParsedModule, call_name, dotted,
                                  function_bodies, own_nodes)

RULE = "journal-before-mutate"
DOC = ("extmgr.free / dev.trim / memtier.invalidate / memtier.fence in the "
       "extent-lease core not dominated by a lease check, scoped lease, or "
       "lease-journal record")

FILES = ("fs.py", "extents.py", "rebalance.py", "memtier.py")

_MUTATORS = (("extmgr", "free"), ("dev", "trim"),
             ("memtier", "invalidate"), ("memtier", "fence"))
_GUARD_CALLS = {"_check_not_leased", "lease_scope", "write_lease",
                "read_lease", "grant_lease"}
_JOURNAL_OPS = {"append_grant", "append_release", "compact", "replay",
                "drop_outstanding"}


def _mutator(call: ast.Call) -> bool:
    if not isinstance(call.func, ast.Attribute):
        return False
    chain = dotted(call.func)
    if chain is None:
        return False
    parts = chain.split(".")
    if len(parts) < 2:
        return False
    recv, attr = parts[-2], parts[-1]
    return (recv, attr) in _MUTATORS


def _guard(call: ast.Call) -> bool:
    name = call_name(call)
    if name in _GUARD_CALLS:
        return True
    if name in _JOURNAL_OPS and isinstance(call.func, ast.Attribute):
        chain = (dotted(call.func) or "").lower()
        return "journal" in chain
    return False


def check(mod: ParsedModule) -> Iterable[Finding]:
    if mod.path.name not in FILES:
        return
    for fn_name, body in function_bodies(mod.tree):
        guards: List[int] = []
        mutators: List[Tuple[int, ast.Call]] = []
        for node in own_nodes(body):
            if not isinstance(node, ast.Call):
                continue
            if _guard(node):
                guards.append(node.lineno)
            elif _mutator(node):
                mutators.append((node.lineno, node))
        for line, call in mutators:
            if any(g <= line for g in guards):
                continue
            yield mod.finding(
                call, RULE,
                f"{dotted(call.func)}() in {fn_name}() is not dominated by "
                "a lease check, scoped lease, or lease-journal record — "
                "freeing/trimming possibly-leased blocks",
            )
