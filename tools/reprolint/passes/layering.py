"""layering — the import DAG between subsystems is enforced, not implied.

The repo's layer map (docs/ARCHITECTURE.md) is a DAG: the storage core
must not know about the planes built on top of it, the kernels must stay
host-logic-free, and the simulator must not reach into the serving plane.
Before this pass, that was convention; a single convenience import could
invert a layer silently. Rules (source prefix → forbidden prefixes):

  * ``repro.core``    ✗→ ``repro.serve``, ``repro.sim``, ``repro.data``
  * ``repro.kernels`` ✗→ ``repro.core``
  * ``repro.sim``     ✗→ ``repro.serve``
  * ``repro.core.memtier`` ✗→ ``repro.core.fs``, ``repro.core.engine``,
    ``repro.core.offloader``, ``repro.core.router`` — the cache tier sits
    BELOW the file system: fs/engine/router import memtier, never the
    reverse (coherence is driven top-down by the lease plane).

Both module-level and function-level (lazy) imports are checked — a lazy
import still creates the dependency. Only ``src/``-rooted modules have a
layer identity; scripts (benchmarks, tools, tests) may import anything.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Tuple

from tools.reprolint.core import Finding, ParsedModule

RULE = "layering"
DOC = ("import-graph DAG: core never imports serve/sim/data, kernels "
       "never imports core, sim never imports serve, memtier never "
       "imports the fs/engine/offloader/router layers above it")

LAYER_RULES: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    ("repro.core", ("repro.serve", "repro.sim", "repro.data")),
    ("repro.kernels", ("repro.core",)),
    ("repro.sim", ("repro.serve",)),
    ("repro.core.memtier", ("repro.core.fs", "repro.core.engine",
                            "repro.core.offloader", "repro.core.router")),
)


def _under(mod: str, prefix: str) -> bool:
    return mod == prefix or mod.startswith(prefix + ".")


def _imported_modules(tree: ast.Module) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            out.extend((node.lineno, a.name) for a in node.names)
        elif (isinstance(node, ast.ImportFrom) and node.level == 0
              and node.module):
            out.append((node.lineno, node.module))
    return out


def check(mod: ParsedModule) -> Iterable[Finding]:
    if mod.module is None:
        return
    for src_prefix, forbidden in LAYER_RULES:
        if not _under(mod.module, src_prefix):
            continue
        for line, target in _imported_modules(mod.tree):
            for bad in forbidden:
                if _under(target, bad):
                    yield Finding(
                        mod.rel, line, RULE,
                        f"{mod.module} (layer {src_prefix}) imports "
                        f"{target}: {src_prefix} must never depend on "
                        f"{bad} (layer inversion)",
                        mod.lines[line - 1].strip(),
                    )
