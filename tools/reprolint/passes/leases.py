"""lease-raw — lease acquisitions must have a structured release path.

The repo's no-DLM correctness story hangs on lease discipline: every
``grant_lease`` (and ``prepare_write(..., lease=True)``) quiesces blocks on
the initiator until the matching ``release_lease``. A call site that grants
raw — outside the scoped context managers ``fs.write_lease`` /
``fs.read_lease`` / ``fs.lease_scope`` and without a ``try``-structured
release — leaks quiesced blocks on any exception between grant and release.

A raw grant is accepted when its enclosing function releases structurally:

  * the grant is inside (or immediately precedes) a ``try`` whose
    ``finally`` calls ``release_lease``; or
  * the ``try`` releases in BOTH an exception handler and the ``else``
    branch — the crash-semantics CM pattern (``lease_scope`` itself):
    a ``BaseException`` that is not an ``Exception`` deliberately leaves
    the journaled grant for remount fencing.

Everything else is flagged. Known-legit sites (a lease that escapes to a
completion callback, a benchmark that manufactures orphans on purpose)
carry ``# reprolint: allow[lease-raw] <reason>`` inline suppressions.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from tools.reprolint.core import Finding, ParsedModule, call_name, own_nodes

RULE = "lease-raw"
DOC = ("grant_lease / prepare_write(lease=True) outside the scoped lease "
       "CMs and without a try-structured release_lease path")

_GRANTS = ("grant_lease",)


def _is_grant(call: ast.Call) -> bool:
    name = call_name(call)
    if name in _GRANTS:
        return True
    if name == "prepare_write":
        return any(
            kw.arg == "lease"
            and isinstance(kw.value, ast.Constant) and kw.value.value is True
            for kw in call.keywords
        )
    return False


def _calls_release(stmts) -> bool:
    return any(
        isinstance(node, ast.Call) and call_name(node) == "release_lease"
        for node in own_nodes(stmts)
    )


def _releasing_tries(body) -> List[ast.Try]:
    out = []
    for node in own_nodes(body):
        if not isinstance(node, ast.Try):
            continue
        if _calls_release(node.finalbody):
            out.append(node)
            continue
        # crash-semantics pattern: release in a handler AND in else —
        # plain failure and success both release; simulated process death
        # (BaseException) leaves the journaled grant for remount fencing
        handler_rel = any(_calls_release(h.body) for h in node.handlers)
        if handler_rel and _calls_release(node.orelse):
            out.append(node)
    return out


def check(mod: ParsedModule) -> Iterable[Finding]:
    for fn_name, body in _functions(mod.tree):
        tries = _releasing_tries(body)
        for node in own_nodes(body):
            if not (isinstance(node, ast.Call) and _is_grant(node)):
                continue
            if any(_covers(t, node) for t in tries):
                continue
            yield mod.finding(
                node, RULE,
                f"raw lease acquisition in {fn_name}() without a scoped CM "
                "(fs.write_lease/read_lease/lease_scope) or try-structured "
                "release_lease",
            )


def _covers(t: ast.Try, grant: ast.Call) -> bool:
    """The try releases this grant: the grant happens inside its body, or
    the try begins at/after the grant line (grant-then-try-release)."""
    if t.lineno >= grant.lineno:
        return True
    in_body = any(
        grant is sub
        for stmt in t.body
        for sub in ast.walk(stmt)
    )
    return in_body


def _functions(tree: ast.Module):
    from tools.reprolint.core import function_bodies

    return function_bodies(tree)
