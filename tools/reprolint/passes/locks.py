"""blocking-under-lock — no blocking calls while holding a mutex.

The offload plane is callback-driven: completion callbacks run on fabric
threads and routinely need the same locks the submitting thread holds. A
blocking call made while holding a ``threading.Lock`` (``time.sleep``, a
synchronous ``fabric.call``/``call_batch``, a blocking ``queue`` get/put,
``OffloadFuture.result``) therefore stalls every other thread contending
for that lock — and can deadlock outright when the blocked-on completion
needs the held lock to make progress (the classic heartbeat-path hang).

Lock regions are ``with <x>.lock / <x>._lock / <x>._mutex:`` blocks (any
receiver chain; ``RLock`` included) plus linear ``<lock>.acquire()`` …
``<lock>.release()`` spans in the same statement list. Condition variables
(``Condition.wait`` releases the lock while waiting) are exempt by naming:
only names matching ``*lock*``/``*mutex*`` count as locks. Nested function
bodies are NOT part of the region — a callback defined under a lock runs
later, without it.

Flagged calls inside a region:

  * ``time.sleep(...)``
  * ``<...fabric...>.call(...)`` / ``.call_batch(...)`` — the synchronous
    RPC forms (``call_async``/``call_batch_async`` return futures and are
    fine; blocking on ``.result()`` under the lock is what gets flagged)
  * ``<...queue...>.get(...)`` / ``.put(...)`` without ``block=False``
  * ``<anything>.result(...)`` — future resolution blocks until completion
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Tuple

from tools.reprolint.core import Finding, ParsedModule, dotted, function_bodies

RULE = "blocking-under-lock"
DOC = ("time.sleep / sync fabric.call / blocking queue get-put / "
       "future .result() inside a held-lock region")

_LOCKY = ("lock", "mutex")
_BLOCKING_SET = {"result"}  # any receiver: future resolution
_QUEUE_OPS = {"get", "put"}


def _is_lock_name(name: Optional[str]) -> bool:
    if not name:
        return False
    leaf = name.rsplit(".", 1)[-1].lower()
    return any(k in leaf for k in _LOCKY)


def _lock_ctx(with_node: ast.With) -> Optional[str]:
    for item in with_node.items:
        ctx = item.context_expr
        if isinstance(ctx, ast.Call):
            ctx = ctx.func  # e.g. lock.acquire_timeout(...) style wrappers
        name = dotted(ctx)
        if _is_lock_name(name):
            return name
    return None


def _blocking_reason(call: ast.Call) -> Optional[str]:
    func = call.func
    if isinstance(func, ast.Name):
        return "time.sleep" if func.id == "sleep" else None
    if not isinstance(func, ast.Attribute):
        return None
    attr = func.attr
    chain = dotted(func) or attr
    recv = chain.rsplit(".", 1)[0].lower() if "." in chain else ""
    if attr == "sleep" and recv.endswith("time"):
        return "time.sleep"
    if attr in ("call", "call_batch") and "fabric" in recv:
        return f"synchronous fabric.{attr}"
    if attr in _BLOCKING_SET:
        return f"future .{attr}() (blocks until completion)"
    if attr in _QUEUE_OPS and ("queue" in recv or recv.endswith("_q")
                               or recv == "q"):
        nonblocking = any(
            kw.arg == "block"
            and isinstance(kw.value, ast.Constant) and kw.value.value is False
            for kw in call.keywords
        ) or (attr == "get" and any(
            isinstance(a, ast.Constant) and a.value is False
            for a in call.args[:1]
        ))
        if not nonblocking:
            return f"blocking queue .{attr}()"
    return None


def _walk_skip_defs(root: ast.AST):
    """Yield descendants without entering nested function/class bodies
    (code defined there runs later, without the lock)."""
    stack = [root]
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _scan_stmts(mod: ParsedModule, stmts, held: Tuple[str, ...],
                out: List[Finding]) -> None:
    """Walk a statement list tracking held locks; recurse into compound
    statements, skip nested function/class bodies (deferred execution)."""
    active: List[str] = []
    for stmt in stmts:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        # manual acquire()/release() spans at this nesting level
        for node in _walk_skip_defs(stmt):
            if isinstance(node, ast.Call) and isinstance(node.func,
                                                         ast.Attribute):
                name = dotted(node.func.value)
                if _is_lock_name(name):
                    if node.func.attr == "acquire":
                        active.append(name)
                    elif node.func.attr == "release" and name in active:
                        active.remove(name)
        now_held = held + tuple(active)
        if isinstance(stmt, ast.With):
            lock = _lock_ctx(stmt)
            inner = now_held + ((lock,) if lock else ())
            if now_held:  # the with-expressions themselves run under held
                _scan_exprs(mod, [stmt.items], now_held, out)
            _scan_stmts(mod, stmt.body, inner, out)
            continue
        bodies, exprs = _split(stmt)
        if now_held:
            _scan_exprs(mod, exprs, now_held, out)
        for body in bodies:
            _scan_stmts(mod, body, now_held, out)


def _split(stmt: ast.stmt):
    """(nested statement lists, expression groups) of a compound stmt."""
    bodies = []
    for attr in ("body", "orelse", "finalbody"):
        b = getattr(stmt, attr, None)
        if b:
            bodies.append(b)
    for h in getattr(stmt, "handlers", ()) or ():
        bodies.append(h.body)
    # everything not in a nested statement list is expression territory
    nested = {id(s) for b in bodies for s in b}
    exprs = [[c for c in ast.iter_child_nodes(stmt)
              if id(c) not in nested]]
    return bodies, exprs


def _scan_exprs(mod: ParsedModule, groups, held: Tuple[str, ...],
                out: List[Finding]) -> None:
    for group in groups:
        for root in group:
            for node in _walk_skip_defs(root):
                if isinstance(node, ast.Call):
                    reason = _blocking_reason(node)
                    if reason:
                        out.append(mod.finding(
                            node, RULE,
                            f"{reason} while holding {held[-1]}",
                        ))


def check(mod: ParsedModule) -> Iterable[Finding]:
    out: List[Finding] = []
    for _name, body in function_bodies(mod.tree):
        _scan_stmts(mod, body, (), out)
    # function_bodies yields nested defs separately; dedupe by location
    seen = set()
    for f in out:
        key = (f.line, f.message)
        if key not in seen:
            seen.add(key)
            yield f
